//! Correctness anchor for the continuous-batching refactor: on a closed-loop
//! workload with no cancellations and no deadlines, the continuous [`Engine`]
//! (slots reclaimed and refilled mid-decode) must produce **bit-identical**
//! per-request token sequences and outcomes to the retained [`LockstepEngine`]
//! (fixed cohorts drained to completion). Also checks that the streaming sink
//! sees exactly the tokens that end up in the final results, in order.

use std::cell::RefCell;
use std::rc::Rc;

use latmix::coordinator::engine::{
    Engine, EngineConfig, MockExecutor, NativeExecutor, StepExecutor,
};
use latmix::coordinator::{GenRequest, GenResult, KvFormat, KvSpec, LockstepEngine, StreamEvent};
use latmix::data::serving_workload;
use latmix::model::NativeDims;

/// Dims matching `MockExecutor::default()` so mock and native share shapes.
fn mock_dims() -> NativeDims {
    NativeDims {
        vocab: 64,
        d_model: 4,
        n_layers: 2,
        n_heads: 2,
        d_ff: 8,
        kv_seq: 32,
        prefill_len: 8,
    }
}

/// Strip each result down to what parity is defined over.
fn essence(out: &[GenResult]) -> Vec<(u64, usize, Vec<i32>, &'static str)> {
    out.iter()
        .map(|r| (r.id, r.prompt_len, r.tokens.clone(), r.outcome.label()))
        .collect()
}

fn submit_all<F: FnMut(GenRequest)>(reqs: &[(Vec<i32>, usize)], mut push: F) {
    for (i, (prompt, max_new)) in reqs.iter().enumerate() {
        push(GenRequest::new(i as u64, prompt.clone(), *max_new));
    }
}

/// [`assert_parity`] with an explicit paged-KV spec on the continuous
/// engine. The lockstep reference always keeps dense per-lane planes, so
/// this pins the paged path (page-table gather, COW sharing, append) to
/// the dense layout bit for bit.
fn assert_parity_kv<E: StepExecutor>(
    make_exec: impl Fn() -> E,
    max_slots: usize,
    reqs: &[(Vec<i32>, usize)],
    kv: KvSpec,
    tag: &str,
) {
    let cfg = EngineConfig { max_slots, eos: -1, kv, ..Default::default() };

    let mut cont = Engine::new(make_exec(), cfg.clone());
    submit_all(reqs, |r| cont.submit(r));
    let cont_out = cont.run_to_completion().unwrap();

    let mut lock = LockstepEngine::new(make_exec(), cfg);
    submit_all(reqs, |r| lock.submit(r));
    let lock_out = lock.run_to_completion().unwrap();

    assert_eq!(cont_out.len(), reqs.len(), "{tag}: continuous engine lost requests");
    assert_eq!(lock_out.len(), reqs.len(), "{tag}: lockstep engine lost requests");
    assert_eq!(
        essence(&cont_out),
        essence(&lock_out),
        "{tag}: continuous and lockstep token sequences diverged"
    );
}

/// Run the same request set through both engines on fresh executors and
/// demand identical per-request (tokens, outcome) per id.
fn assert_parity<E: StepExecutor>(
    make_exec: impl Fn() -> E,
    max_slots: usize,
    reqs: &[(Vec<i32>, usize)],
    tag: &str,
) {
    assert_parity_kv(make_exec, max_slots, reqs, KvSpec::default(), tag);
}

#[test]
fn continuous_matches_lockstep_mock() {
    for (seed, n, slots) in [(3u64, 12usize, 3usize), (11, 9, 2), (29, 17, 4), (5, 1, 3)] {
        let reqs = serving_workload(n, 6, 8, seed);
        assert_parity(
            MockExecutor::default,
            slots,
            &reqs,
            &format!("mock seed={seed} n={n} slots={slots}"),
        );
    }
}

#[test]
fn continuous_matches_lockstep_native_small() {
    // Real forward pass (mock-shaped dims): lane-order independence of the
    // native decode is what makes the parity hold — prove it end to end.
    for (seed, n, slots) in [(7u64, 10usize, 3usize), (23, 6, 2)] {
        let reqs = serving_workload(n, 6, 7, seed);
        assert_parity(
            || NativeExecutor::synthetic(mock_dims(), "fp", vec![1, 2, 4], 17).unwrap(),
            slots,
            &reqs,
            &format!("native seed={seed} n={n} slots={slots}"),
        );
    }
}

#[test]
fn continuous_matches_lockstep_latmix_tiny() {
    // The shipped tiny config, quantized spec included.
    let dims = NativeDims::latmix_tiny();
    for tag in ["fp", "mxfp4_b32_t3"] {
        let reqs = serving_workload(8, 6, 6, 41);
        assert_parity(
            || NativeExecutor::synthetic(dims, tag, vec![1, 2, 4, 8], 3).unwrap(),
            4,
            &reqs,
            &format!("latmix_tiny tag={tag}"),
        );
    }
}

#[test]
fn packed_weights_match_dequantized_token_streams() {
    // The fused packed-GEMM gate: serving on MX-packed weights must emit
    // token streams bit-identical to serving on the SAME packed bytes
    // dequantized back to f32 and run through the dense kernel. (Packing
    // is lossy vs the raw f32 weights; the parity is packed-vs-dequantized,
    // not packed-vs-raw.)
    use latmix::model::NativeWeights;
    use latmix::mx::MxConfig;

    let dims = NativeDims::latmix_tiny();
    for (tag, fmt, bs) in [("mxfp4_b32_t3", "mxfp4", 32usize), ("mxint4_b32", "mxint4", 32)] {
        let cfg = MxConfig::from_name(fmt, Some(bs)).unwrap();
        let raw = NativeWeights::synthetic(dims, 3);
        let dq = raw.pack_weights(cfg).unwrap().unpack_weights();

        let reqs = serving_workload(8, 6, 6, 41);
        let ecfg = EngineConfig { max_slots: 4, eos: -1, ..Default::default() };

        let packed_exec = NativeExecutor::synthetic(dims, tag, vec![1, 2, 4, 8], 3)
            .unwrap()
            .into_packed()
            .unwrap();
        assert!(packed_exec.packed_weights(), "{tag}: executor must report packed storage");
        assert!(
            packed_exec.resident_weight_bytes() < dq.weight_bytes(),
            "{tag}: packed residency must undercut dense f32"
        );
        let mut packed_eng = Engine::new(packed_exec, ecfg.clone());
        submit_all(&reqs, |r| packed_eng.submit(r));
        let packed_out = packed_eng.run_to_completion().unwrap();

        let dq_exec = NativeExecutor::from_weights(dq, tag, vec![1, 2, 4, 8]).unwrap();
        let mut dq_eng = Engine::new(dq_exec, ecfg);
        submit_all(&reqs, |r| dq_eng.submit(r));
        let dq_out = dq_eng.run_to_completion().unwrap();

        assert_eq!(
            essence(&packed_out),
            essence(&dq_out),
            "{tag}: packed and dequantized token streams diverged"
        );
    }
}

#[test]
fn paged_fp_parity_is_page_size_invariant() {
    // fp-precision paged KV must be bit-identical to the dense lockstep
    // reference whatever the page size — including pages smaller than a
    // prompt, a one-token degenerate page, and a page size that leaves a
    // ragged final page on every prompt.
    let reqs = serving_workload(10, 6, 8, 19);
    for block in [1usize, 3, 4, 16] {
        assert_parity_kv(
            MockExecutor::default,
            3,
            &reqs,
            KvSpec { format: KvFormat::F32, block },
            &format!("mock paged block={block}"),
        );
    }
    let dims = NativeDims::latmix_tiny();
    let reqs = serving_workload(8, 6, 6, 41);
    for block in [4usize, 7] {
        assert_parity_kv(
            || NativeExecutor::synthetic(dims, "fp", vec![1, 2, 4, 8], 3).unwrap(),
            4,
            &reqs,
            KvSpec { format: KvFormat::F32, block },
            &format!("latmix_tiny paged block={block}"),
        );
    }
}

#[test]
fn shared_prefix_keeps_parity_and_shares_pages() {
    // Prompts that agree on a long prefix: the paged engine maps the
    // prefix pages once and refcounts them. Token streams must still be
    // bit-identical to the dense lockstep reference (K/V rows are lane-
    // independent), the share counter must climb, and the pool must stay
    // below what dense per-slot planes would hold.
    let dims = NativeDims::latmix_tiny();
    let mut reqs = serving_workload(10, 16, 6, 23);
    let prefix = reqs[0].0.clone();
    for (p, _) in reqs.iter_mut() {
        let n = p.len().min(8);
        p[..n].copy_from_slice(&prefix[..n]);
    }
    let kv = KvSpec { format: KvFormat::F32, block: 4 };
    assert_parity_kv(
        || NativeExecutor::synthetic(dims, "fp", vec![1, 2, 4, 8], 3).unwrap(),
        4,
        &reqs,
        kv,
        "latmix_tiny shared prefix",
    );

    let mut eng = Engine::new(
        NativeExecutor::synthetic(dims, "fp", vec![1, 2, 4, 8], 3).unwrap(),
        EngineConfig { max_slots: 4, eos: -1, kv, ..Default::default() },
    );
    submit_all(&reqs, |r| eng.submit(r));
    eng.run_to_completion().unwrap();
    assert!(eng.kv_pages_shared() > 0, "8-token shared prefix must share 4-token pages");
    assert!(
        eng.kv_resident_bytes() < eng.kv_dense_bytes(),
        "paged pool ({} B) must stay under dense per-slot planes ({} B)",
        eng.kv_resident_bytes(),
        eng.kv_dense_bytes()
    );
}

#[test]
fn mxfp8_kv_is_flip_tolerant_vs_fp_kv() {
    // The quantized-KV gate, shaped like the packed-weights one: MXFP8
    // pages perturb decode inputs, so token streams may flip — but the
    // structure must hold. Same requests complete, first generated token
    // is bit-identical (it comes from prefill logits, computed before any
    // KV row is stored), and the overall token agreement stays high.
    let dims = NativeDims::latmix_tiny();
    let reqs = serving_workload(8, 6, 8, 41);
    let run = |kv: KvSpec| -> Vec<GenResult> {
        let mut eng = Engine::new(
            NativeExecutor::synthetic(dims, "fp", vec![1, 2, 4, 8], 3).unwrap(),
            EngineConfig { max_slots: 4, eos: -1, kv, ..Default::default() },
        );
        submit_all(&reqs, |r| eng.submit(r));
        let mut out = eng.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        out
    };
    let fp = run(KvSpec::default());
    let q8 = run(KvSpec { format: KvFormat::Mxfp8, block: 16 });
    assert_eq!(fp.len(), q8.len());
    let (mut agree, mut total) = (0usize, 0usize);
    for (a, b) in fp.iter().zip(&q8) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.prompt_len, b.prompt_len);
        assert!(b.outcome.is_complete(), "req {}: quantized run must complete", b.id);
        assert_eq!(
            a.tokens.first(),
            b.tokens.first(),
            "req {}: first token comes from prefill logits and may not flip",
            a.id
        );
        let n = a.tokens.len().min(b.tokens.len());
        total += n;
        agree += (0..n).filter(|&i| a.tokens[i] == b.tokens[i]).count();
    }
    let frac = agree as f64 / total.max(1) as f64;
    assert!(frac >= 0.6, "mxfp8 KV token agreement {frac:.2} below flip-tolerance floor");
}

#[test]
fn packed_weights_rejected_on_fp_tag() {
    // fp graphs have no MX config to pack against — into_packed must error,
    // not silently serve unquantized.
    let exec = NativeExecutor::synthetic(NativeDims::latmix_tiny(), "fp", vec![1, 2], 3).unwrap();
    let err = exec.into_packed().unwrap_err().to_string();
    assert!(err.contains("quantized"), "unexpected error: {err}");
}

#[test]
fn stream_events_reassemble_final_tokens() {
    // Every Token event must land in order, and the reassembled per-request
    // streams must equal the final GenResult token sequences exactly.
    let reqs = serving_workload(11, 6, 8, 13);
    let seen: Rc<RefCell<Vec<StreamEvent>>> = Rc::new(RefCell::new(Vec::new()));
    let sink_seen = Rc::clone(&seen);
    let mut eng = Engine::new(
        MockExecutor::default(),
        EngineConfig { max_slots: 3, eos: -1, ..Default::default() },
    );
    eng.set_sink(Box::new(move |ev| sink_seen.borrow_mut().push(ev.clone())));
    submit_all(&reqs, |r| eng.submit(r));
    let out = eng.run_to_completion().unwrap();

    let mut streams: std::collections::HashMap<u64, Vec<i32>> = Default::default();
    let mut finished: std::collections::HashMap<u64, usize> = Default::default();
    for ev in seen.borrow().iter() {
        match ev {
            StreamEvent::Token { id, index, token, .. } => {
                let s = streams.entry(*id).or_default();
                assert_eq!(*index, s.len(), "req {id}: out-of-order token index");
                s.push(*token);
            }
            StreamEvent::Finished { id, n_tokens, .. } => {
                assert!(finished.insert(*id, *n_tokens).is_none(), "req {id} finished twice");
            }
        }
    }
    assert_eq!(out.len(), reqs.len());
    for r in &out {
        assert_eq!(
            streams.get(&r.id).cloned().unwrap_or_default(),
            r.tokens,
            "req {}: streamed tokens != final tokens",
            r.id
        );
        assert_eq!(
            finished.get(&r.id),
            Some(&r.tokens.len()),
            "req {}: bad Finished event",
            r.id
        );
    }
}
