//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These need the `backend-xla` build feature (the whole file is
//! feature-gated) plus `make artifacts` (graphs + fp_raw weights); they
//! self-skip with a notice when artifacts are absent so `cargo test` stays
//! green on a fresh clone. The artifact-free engine coverage lives in
//! `backend_parity.rs` and runs on every build.

#![cfg(feature = "backend-xla")]

use latmix::coordinator::engine::StepExecutor;
use latmix::coordinator::{Engine, EngineConfig, GenRequest};
use latmix::data::{load_ppl_corpus, load_tasks};
use latmix::eval::{perplexity, zero_shot};
use latmix::model::{ModelDesc, WeightSet};
use latmix::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let art = latmix::artifacts_dir();
    if !art.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    let desc = ModelDesc::load(&art).unwrap();
    if !desc.weights_path("fp_raw").exists() {
        eprintln!("skipping: no fp_raw weights (run `make pretrain artifacts`)");
        return None;
    }
    Some(Runtime::new(desc).unwrap())
}

#[test]
fn fp_perplexity_matches_python() {
    let Some(rt) = runtime() else { return };
    let ws = WeightSet::load(&rt.desc, "fp_raw").unwrap();
    let art = latmix::artifacts_dir();
    let (corpus, n, t) = load_ppl_corpus(&art).unwrap();
    let ppl = perplexity(&rt, "fp", &ws, &corpus, n, t).unwrap();
    // python train_lm reports heldout ppl ~9 on this corpus; the graph
    // execution must land in the same regime (fused-vs-eager gives ~1e-5
    // logit differences only).
    assert!(ppl > 2.0 && ppl < 30.0, "fp ppl {ppl} out of range");
}

#[test]
fn quantized_ppl_ordering() {
    let Some(rt) = runtime() else { return };
    let ws = WeightSet::load(&rt.desc, "fp_raw").unwrap();
    let art = latmix::artifacts_dir();
    let (corpus, n, t) = load_ppl_corpus(&art).unwrap();
    let fp = perplexity(&rt, "fp", &ws, &corpus, n, t).unwrap();
    // fp weights under activation quantization: worse than fp, finite.
    let q = perplexity(&rt, "mxfp4_b32", &ws, &corpus, n, t).unwrap();
    assert!(q > fp, "act-quant ppl {q} should exceed fp {fp}");
    assert!(q < fp * 40.0, "act-quant ppl {q} unreasonably bad");
}

#[test]
fn zero_shot_beats_chance_fp() {
    let Some(rt) = runtime() else { return };
    let ws = WeightSet::load(&rt.desc, "fp_raw").unwrap();
    let tasks = load_tasks(&latmix::artifacts_dir()).unwrap();
    let accs = zero_shot(&rt, "fp", &ws, &tasks).unwrap();
    let avg = accs.last().unwrap().1;
    assert!(avg > 0.30, "fp zero-shot avg {avg} should beat chance (0.25)");
}

#[test]
fn serving_engine_end_to_end() {
    let Some(rt) = runtime() else { return };
    let ws = WeightSet::load(&rt.desc, "fp_raw").unwrap();
    let exec =
        latmix::coordinator::engine::XlaExecutor::new(&rt, "fp", &ws).unwrap();
    let mut engine =
        Engine::new(exec, EngineConfig { max_slots: 4, eos: -1, ..Default::default() });
    for i in 0..5u64 {
        engine.submit(GenRequest::new(i, vec![1, 40 + i as i32, 50], 6));
    }
    let out = engine.run_to_completion().unwrap();
    assert_eq!(out.len(), 5);
    for r in &out {
        assert_eq!(r.tokens.len(), 6);
        for t in &r.tokens {
            assert!(*t >= 0 && (*t as usize) < engine.exec.vocab());
        }
    }
    assert!(engine.stats.decode_tokens >= 30);
}

#[test]
fn native_executor_agrees_with_xla_on_artifacts() {
    // Cross-backend check on real artifacts: identical compiled-batch
    // discovery, and the same request stream produces the same scheduling
    // shape (token counts + engine stats) through both executors.
    let Some(rt) = runtime() else { return };
    let ws = WeightSet::load(&rt.desc, "fp_raw").unwrap();
    let xla_exec = latmix::coordinator::engine::XlaExecutor::new(&rt, "fp", &ws).unwrap();
    let native_exec =
        latmix::coordinator::engine::NativeExecutor::new(&rt.desc, "fp", &ws).unwrap();
    assert_eq!(
        xla_exec.batch_sizes(),
        native_exec.batch_sizes(),
        "backends disagree on compiled batch sizes"
    );

    fn run_stream<E: StepExecutor>(
        mut engine: Engine<E>,
    ) -> (Vec<usize>, u64, u64, u64, u64, u64) {
        for i in 0..6u64 {
            engine.submit(GenRequest::new(i, vec![1, 40 + i as i32, 50], 5));
        }
        let out = engine.run_to_completion().unwrap();
        let counts: Vec<usize> = out.iter().map(|r| r.tokens.len()).collect();
        let events = latmix::runtime::sched_fingerprint(engine.events());
        let s = engine.stats.clone();
        (counts, s.prefill_batches, s.decode_steps, s.decode_lanes, s.decode_tokens, events)
    }
    let cfg = EngineConfig { max_slots: 4, eos: -1, ..Default::default() };
    let a = run_stream(Engine::new(xla_exec, cfg.clone()));
    let b = run_stream(Engine::new(native_exec, cfg));
    assert_eq!(a, b, "scheduling diverged between XLA and native executors");
}

#[test]
fn decode_matches_logits_graph() {
    // Consistency across graph kinds: greedy continuation via prefill+decode
    // must equal argmax chaining on the full-sequence logits graph.
    let Some(rt) = runtime() else { return };
    let ws = WeightSet::load(&rt.desc, "fp_raw").unwrap();
    let exec =
        latmix::coordinator::engine::XlaExecutor::new(&rt, "fp", &ws).unwrap();
    let prompt = vec![1i32, 40, 41, 42];
    let mut engine =
        Engine::new(exec, EngineConfig { max_slots: 1, eos: -1, ..Default::default() });
    engine.submit(GenRequest::new(0, prompt.clone(), 4));
    let out = engine.run_to_completion().unwrap();
    let via_engine = out[0].tokens.clone();

    // reference: run logits graph step by step over growing sequence
    use latmix::runtime::{i32_literal, literal_to_f32};
    let weights = rt.stage_weights(&ws).unwrap();
    let (gb, gt) = rt.desc.ppl_shape;
    let vocab = rt.desc.vocab;
    let mut seq = prompt.clone();
    let mut via_logits = Vec::new();
    for _ in 0..4 {
        let mut toks = vec![0i32; gb * gt];
        toks[..seq.len()].copy_from_slice(&seq);
        let mut inputs = vec![i32_literal(&toks, &[gb as i64, gt as i64]).unwrap()];
        for w in &weights {
            let dims: Vec<i64> = w.array_shape().unwrap().dims().to_vec();
            inputs.push(w.reshape(&dims).unwrap());
        }
        let parts = rt.execute("logits_ppl_fp", &inputs).unwrap();
        let logits = literal_to_f32(&parts[0]).unwrap();
        let row = &logits[(seq.len() - 1) * vocab..seq.len() * vocab];
        let next = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        via_logits.push(next);
        seq.push(next);
    }
    assert_eq!(via_engine, via_logits, "KV decode path diverges from full-seq path");
}
