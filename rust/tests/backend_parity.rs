//! Artifact-free cross-backend coverage: the pure-Rust [`NativeExecutor`]
//! must drive `Batcher`/`Scheduler`/`KvCache` exactly like the mock (and,
//! by the shared batch parser, like the XLA executor), and compiled-batch
//! selection must pick the smallest compiled size covering the active
//! lanes on every backend. Runs with and without the `backend-xla` feature.

use latmix::coordinator::engine::{
    Engine, EngineConfig, MockExecutor, NativeExecutor, StepExecutor,
};
use latmix::coordinator::{Batcher, GenRequest};
use latmix::model::{NativeDims, NativeWeights};
use latmix::runtime::{decode_batch_sizes, sched_fingerprint};

/// Dims matching `MockExecutor::default()` (vocab 64, 2 layers, kv_seq 32,
/// kv_row/d_model 4, prefill 8) so both executors schedule identically.
fn mock_dims() -> NativeDims {
    NativeDims {
        vocab: 64,
        d_model: 4,
        n_layers: 2,
        n_heads: 2,
        d_ff: 8,
        kv_seq: 32,
        prefill_len: 8,
    }
}

fn native_like_mock() -> NativeExecutor {
    NativeExecutor::synthetic(mock_dims(), "fp", vec![1, 2, 4], 17).unwrap()
}

/// Scheduling fingerprint of one engine run: per-request token counts,
/// every batching/decode counter the engine keeps, and the hash of the
/// full admit/refill/evict event log (`runtime::sched_fingerprint`) — two
/// backends that schedule identically must agree on every component.
fn fingerprint<E: StepExecutor>(
    exec: E,
    reqs: &[(Vec<i32>, usize)],
) -> (Vec<(u64, usize)>, u64, u64, u64, u64, u64, u64) {
    let mut engine = Engine::new(
        exec,
        EngineConfig { max_slots: 3, eos: -1, ..Default::default() },
    );
    for (i, (prompt, max_new)) in reqs.iter().enumerate() {
        engine.submit(GenRequest::new(i as u64, prompt.clone(), *max_new));
    }
    let out = engine.run_to_completion().unwrap();
    let counts: Vec<(u64, usize)> = out.iter().map(|r| (r.id, r.tokens.len())).collect();
    let events = sched_fingerprint(engine.events());
    let s = &engine.stats;
    (
        counts,
        s.prefill_batches,
        s.decode_steps,
        s.decode_lanes,
        s.prefill_tokens,
        s.decode_tokens,
        events,
    )
}

#[test]
fn native_matches_mock_scheduling() {
    // Several workload shapes: bursty, staggered lengths, single request.
    let workloads: Vec<Vec<(Vec<i32>, usize)>> = vec![
        vec![(vec![1, 2, 3], 4); 9],
        (0..7)
            .map(|i| ((0..=(i % 5) as i32).collect::<Vec<i32>>(), 1 + (i * 2) % 6))
            .collect(),
        vec![(vec![5, 6], 7)],
    ];
    for (wi, reqs) in workloads.iter().enumerate() {
        let mock = fingerprint(MockExecutor::default(), reqs);
        let native = fingerprint(native_like_mock(), reqs);
        assert_eq!(
            mock, native,
            "workload {wi}: scheduling decisions / token counts diverged"
        );
    }
}

#[test]
fn compiled_batch_selection_smallest_covering() {
    // The shared parser feeds both backends; Batcher::bucket_for must pick
    // the smallest compiled batch >= active lanes (largest when overflowed).
    let graphs: Vec<String> = ["decode_fp_b1", "decode_fp_b2", "decode_fp_b4", "prefill_fp_b4"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let parsed = decode_batch_sizes(&graphs, "fp");
    assert_eq!(parsed, vec![1, 2, 4]);

    let native = native_like_mock();
    let mock = MockExecutor::default();
    assert_eq!(native.batch_sizes(), parsed);
    assert_eq!(mock.batch_sizes(), parsed);

    for exec_batches in [native.batch_sizes(), mock.batch_sizes()] {
        let b = Batcher::new(exec_batches);
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(2), 2);
        assert_eq!(b.bucket_for(3), 4, "3 lanes must ride the b=4 graph");
        assert_eq!(b.bucket_for(4), 4);
        assert_eq!(b.bucket_for(9), 4, "overflow clamps to largest compiled batch");
    }
}

#[test]
fn malformed_decode_graphs_are_not_selected() {
    let graphs: Vec<String> = [
        "decode_fp_b2",
        "decode_fp_bogus", // malformed suffix: warned, never selected
        "decode_fp_b0",    // zero batch: warned, never selected
        "decode_mxfp4_b32_t3_b4",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(decode_batch_sizes(&graphs, "fp"), vec![2]);
    assert_eq!(decode_batch_sizes(&graphs, "mxfp4_b32_t3"), vec![4]);
}

#[test]
fn native_executor_serves_end_to_end() {
    // Realistic dims (the latmix-tiny shape) through the full engine loop,
    // quantized spec included — the no-artifact mirror of
    // `serving_engine_end_to_end` in integration_runtime.rs.
    let dims = NativeDims::latmix_tiny();
    for tag in ["fp", "mxfp4_b32_t3"] {
        let exec = NativeExecutor::synthetic(dims, tag, vec![1, 2, 4, 8], 3).unwrap();
        let vocab = exec.vocab();
        let mut engine = Engine::new(
            exec,
            EngineConfig { max_slots: 4, eos: -1, ..Default::default() },
        );
        for i in 0..5u64 {
            engine.submit(GenRequest::new(i, vec![1, 40 + i as i32, 50], 6));
        }
        let out = engine.run_to_completion().unwrap();
        assert_eq!(out.len(), 5, "tag {tag}: not all requests completed");
        for r in &out {
            assert_eq!(r.tokens.len(), 6);
            for t in &r.tokens {
                assert!(*t >= 0 && (*t as usize) < vocab, "tag {tag}: token out of range");
            }
        }
        assert!(engine.stats.decode_tokens >= 30);
    }
}

#[test]
fn native_executor_loads_weight_sets() {
    // The `.lxt` WeightSet path (what `NativeExecutor::new` uses under
    // artifacts) must parse back into exactly the generating weights.
    let dims = mock_dims();
    let w = NativeWeights::synthetic(dims, 99);
    let (order, ws) = w.to_weight_set("fp_synth");
    let parsed = NativeWeights::from_weight_set(dims, &order, &ws).unwrap();
    assert_eq!(w, parsed);

    let exec = NativeExecutor::from_weights(parsed, "fp", vec![1, 2]).unwrap();
    // and it must actually step: one prefill + one decode
    let mut tokens = vec![0i32; exec.prefill_len()];
    tokens[..3].copy_from_slice(&[1, 5, 9]);
    let (logits, kv) = exec.prefill(&tokens, &[3], 1).unwrap();
    assert_eq!(logits.len(), exec.vocab());
    assert_eq!(kv.len(), exec.n_layers() * 2);
    let (logits2, kv2) = exec.decode(&[7], &[3], &kv, 1).unwrap();
    assert_eq!(logits2.len(), exec.vocab());
    assert_eq!(kv2.len(), kv.len());
}
